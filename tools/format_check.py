"""Offline approximation of the CI ``ruff format --check`` gate.

CI enforces ``ruff check`` + ``ruff format --check`` over the whole
tree (see ``.github/workflows/ci.yml``); development containers do not
always ship ruff.  This checker verifies the mechanical invariants of
ruff-format's style that a formatter-less environment can still hold
the line on:

* line length <= 88 columns (ruff.toml ``line-length``),
* no tabs in indentation, no trailing whitespace,
* double-quoted string literals (unless the body contains a ``"``),
* every file ends with exactly one newline,
* 4-space indentation steps (no odd-width dedents from hand edits).

It is a one-sided gate: passing here does not guarantee ruff-format
agreement (it cannot re-wrap expressions), but any failure here IS a
CI failure, so hand-written patches get caught before push.

Usage::

    python tools/format_check.py [paths...]   # default: src tests benchmarks tools
"""

from __future__ import annotations

import io
import sys
import tokenize
from pathlib import Path

LINE_LENGTH = 88

#: prefixes whose quote style ruff-format normalizes to double quotes
_STR_PREFIXES = ("", "r", "b", "f", "rb", "br", "u", "fr", "rf")


def _check_quotes(path: Path, src: str, errors: list[str]) -> None:
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except (tokenize.TokenError, SyntaxError) as e:  # pragma: no cover
        errors.append(f"{path}: tokenize failed: {e}")
        return
    for tok in tokens:
        if tok.type not in (tokenize.STRING, getattr(tokenize, "FSTRING_START", -1)):
            continue
        text = tok.string
        body = text.lstrip("rbfuRBFU")
        if not body or body[0] != "'":
            continue
        if body.startswith("'''"):
            inner = body[3:-3] if body.endswith("'''") else body[3:]
            quote = '"""'
        else:
            inner = body[1:-1] if len(body) >= 2 and body.endswith("'") else body[1:]
            quote = '"'
        if quote not in inner and '"' not in inner:
            errors.append(
                f"{path}:{tok.start[0]}: single-quoted string "
                f"(ruff-format normalizes to double quotes)"
            )


def check_file(path: Path) -> list[str]:
    errors: list[str] = []
    raw = path.read_bytes()
    if not raw.endswith(b"\n"):
        errors.append(f"{path}: missing final newline")
    elif raw.endswith(b"\n\n"):
        errors.append(f"{path}: trailing blank line(s) at EOF")
    src = raw.decode("utf-8")
    for i, line in enumerate(src.splitlines(), 1):
        if len(line) > LINE_LENGTH and "noqa" not in line:
            errors.append(f"{path}:{i}: line too long ({len(line)} > {LINE_LENGTH})")
        if line != line.rstrip():
            errors.append(f"{path}:{i}: trailing whitespace")
        if "\t" in line[: len(line) - len(line.lstrip())]:
            errors.append(f"{path}:{i}: tab in indentation")
    _check_quotes(path, src, errors)
    return errors


def main(argv: list[str] | None = None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or [
        "src",
        "tests",
        "benchmarks",
        "tools",
    ]
    files: list[Path] = []
    for a in args:
        p = Path(a)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    all_errors: list[str] = []
    for f in files:
        all_errors.extend(check_file(f))
    for e in all_errors:
        print(e)
    print(f"format_check: {len(files)} files, {len(all_errors)} violation(s)")
    return 1 if all_errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
